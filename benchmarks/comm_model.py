"""Paper Fig. 8 / Table III — per-method latency & energy comparison.

Reproduces the paper's comparison of {flat-ring (Megatron), torus-ring,
Optimus, Hecaton} on the Llama ladder with the paper's hardware regime
(per-die compute/SRAM, standard vs advanced package D2D bandwidth).
Latency is normalized to Hecaton per workload, as in Fig. 8.

Energy model: E = compute_J + nop_bytes * pJ/bit + dram_bytes * pJ/bit with the
paper's §VI-A constants (D2D ~1 pJ/bit class, DRAM 19 pJ/bit).

Overlap-aware extension (per ``ParallelConfig.overlap`` mode): Table III's
transmission terms assume bulk-synchronous collectives — exposed on the
critical path.  The implementation's ring decompositions hide part of that
time behind the per-step matmuls, so the theory here gets a per-mode
*overlap efficiency* (fraction of NoP time that can hide behind compute) and
the derived *effective bandwidth* the links appear to have once hiding is
accounted for.  This keeps the analytical numbers comparable to the per-mode
HLO measurements in hlo_compare.py / overlap.py:

  none   0.00  — bulk collectives fully exposed (Alg. 1 as written)
  ring   0.70  — per-step dispatch gaps + one un-hideable step remain
  bidir  0.80  — half-sized messages both directions shrink each gap
  fused  0.95  — remote DMA double-buffered inside one kernel; only the
                 prologue hop and epilogue drain stay exposed

Wire-dtype axis (``ParallelConfig.comm_dtype``, core/quant.py): int8 rings
move ``1 + 4/h`` bytes per element (payload + amortized per-row fp32 scale)
instead of bf16's 2, so the NoP term — and only the NoP term; compute and
DRAM streaming are untouched — shrinks by ~2x.  :func:`comm_bytes_per_elt`
is the single source of that number, :func:`overlap_rows` takes a
``comm_dtype`` and ``fit_overlap_eff`` a per-mode ``wire`` multiplier so the
calibrated efficiencies stay comparable across wire dtypes.
"""

from __future__ import annotations

from repro.core import theory as T

# fraction of NoP transmission time hidden behind compute, per overlap mode.
# These are the DEFAULT (uncalibrated) values; ``fit_overlap_eff`` below fits
# the table from measured per-mode step times (BENCH_overlap.json via
# ``benchmarks/run.py --calibrate``) and the fitted values are persisted
# alongside the theory rows.
OVERLAP_EFF = {"none": 0.00, "ring": 0.70, "bidir": 0.80, "fused": 0.95}


def comm_bytes_per_elt(comm_dtype: str, h: int) -> float:
    """Wire bytes per element a ring hop moves under ``comm_dtype``.

    bf16 ships the shard as-is (2 B/elt).  int8 ships (int8 payload, fp32
    per-row scale): ``1 + 4/h`` B/elt with the scale amortized over the
    trailing extent — except below ``quant.MIN_QUANT_DIM``, where the hop
    degrades to full width (core/quant.quant_ok) and the bf16 number applies.
    """
    from repro.core import quant as Q
    Q.check_comm_dtype(comm_dtype)
    if comm_dtype == "int8" and h >= Q.MIN_QUANT_DIM:
        return 1.0 + 4.0 / h
    return 2.0


def exposed_comm(comm_s: float, compute_s: float, mode: str,
                 eff=None) -> float:
    """NoP seconds left on the critical path after overlap.

    Hiding is bounded both by the mode's efficiency (``eff`` table, default
    the hardcoded ``OVERLAP_EFF``) and by the compute available to hide
    behind (a ring longer than its matmuls stays exposed)."""
    table = OVERLAP_EFF if eff is None else eff
    hidden = min(table[mode] * comm_s, compute_s)
    return comm_s - hidden


def effective_bandwidth(beta: float, comm_s: float, compute_s: float,
                        mode: str, eff=None) -> float:
    """Apparent link bandwidth once overlap hides part of the transfer."""
    exp = exposed_comm(comm_s, compute_s, mode, eff)
    if exp <= 0:
        return float("inf")
    return beta * comm_s / exp


def overlap_rows(eff=None, comm_dtype="bf16"):
    """Hecaton per-overlap-mode layer latency on the paper ladder (std pkg).

    The same layer_time decomposition as Fig. 8, with the NoP term replaced by
    its exposed (post-overlap) fraction — normalized to the bulk bf16 mode.
    ``eff`` substitutes a calibrated efficiency table (``fit_overlap_eff``)
    for the hardcoded defaults; ``comm_dtype`` rescales ONLY the NoP term by
    :func:`comm_bytes_per_elt` (compute and DRAM streaming keep the compute
    dtype — the quantization lives on the wire)."""
    table = OVERLAP_EFF if eff is None else eff
    beta = PACKAGES["standard"]
    rows = []
    for name, h, N, layers in WORKLOADS:
        p = T.CommParams(N=N, beta=beta, b=8, s=2048, h=h)
        sp = T.SystemParams(comm=p, flops_per_device=DIE_FLOPS,
                            dram_channels=max(8, int(N ** 0.5) * 4))
        lt = T.layer_time("hecaton", sp)
        wire = comm_bytes_per_elt(comm_dtype, h)
        nop_full = lt["nop"] * wire / p.bytes_per_elt
        base = None
        for mode in table:
            nop = exposed_comm(nop_full, lt["compute"], mode, table)
            total = max(lt["compute"] + nop, lt["dram"]) * layers
            if base is None:
                # normalize to bulk *bf16* so int8 rows read as end-to-end
                # speedups over today's exposed baseline
                base = max(lt["compute"]
                           + exposed_comm(lt["nop"], lt["compute"], "none",
                                          table),
                           lt["dram"]) * layers
            rows.append({
                "workload": name, "mode": mode, "comm_dtype": comm_dtype,
                "wire_bytes_per_elt": wire,
                "latency": total,
                "latency_norm": total / base,
                "exposed_nop": nop,
                "eff_bandwidth": effective_bandwidth(
                    beta, nop_full, lt["compute"], mode, table),
            })
    return rows


def fit_overlap_eff(step_times, prior=None, wire=None):
    """Fit per-mode overlap efficiency from measured per-mode step times.

    ``step_times`` is the ``overlap_step_times_us`` payload of
    BENCH_overlap.json: ``{mode: {"<kind>_us": t, ...}}`` with a ``"none"``
    baseline row.  Model per kind *k* and mode *m*:

        t_{k,m} = compute_k + (1 - e_m) * w_m * comm_k, comm_k = rho * t_{k,none}

    ``wire`` maps mode name → wire-byte multiplier ``w_m`` relative to the
    baseline (default 1.0 everywhere); rows measured under
    ``comm_dtype="int8"`` pass ``comm_bytes_per_elt("int8", h) / 2`` so the
    2x byte cut is attributed to the wire, not mistaken for extra overlap
    efficiency.

    The system is underdetermined by exactly one dof (the compute/comm split
    rho), so rho is chosen by a 1-D search minimizing the distance of the
    fitted efficiencies to the ``prior`` table (the hardcoded defaults) —
    i.e. the measurement reshapes the table as far as the data supports and
    shrinks toward the prior where it cannot.  Efficiencies are clipped to
    [0, 1]: on a host-CPU mesh with no async collective engine the ring modes
    can measure *slower* than bulk, which clips to 0 rather than going
    negative (the clip fraction is reported in the diagnostics).

    Returns ``{"eff": {mode: e}, "comm_fraction": rho, "prior_distance": d,
    "clipped": [...]}`` or None if the payload has no usable baseline."""
    prior = dict(OVERLAP_EFF if prior is None else prior)
    if not isinstance(step_times, dict):
        return None
    t = {m: {k: v for k, v in row.items()
             if k.endswith("_us") and isinstance(v, (int, float)) and v > 0}
         for m, row in step_times.items()
         if isinstance(row, dict) and "error" not in row}
    base = t.pop("none", None)
    modes = [m for m in t if t[m]]
    if not base or not modes:
        return None

    wire = dict(wire or {})

    def eff_at(rho):
        eff, clipped = {}, []
        for m in modes:
            w_m = wire.get(m, 1.0)
            vals = []
            for k, tn in base.items():
                tm = t[m].get(k)
                if tm:
                    # invert t_m = (1-rho)t_n + (1-e) w rho t_n for e
                    vals.append(1.0 - (tm - (1.0 - rho) * tn)
                                / (w_m * rho * tn))
            if not vals:
                continue
            raw = sum(vals) / len(vals)
            e = min(1.0, max(0.0, raw))
            if e != raw:
                clipped.append(m)
            eff[m] = e
        return eff, clipped

    best = None
    for i in range(1, 40):
        rho = i / 40.0
        eff, clipped = eff_at(rho)
        score = sum((eff.get(m, 0.0) - prior.get(m, 0.0)) ** 2
                    for m in modes)
        if best is None or score < best[0]:
            best = (score, rho, eff, clipped)
    score, rho, eff, clipped = best
    return {"eff": {"none": 0.0, **eff}, "comm_fraction": rho,
            "prior_distance": score, "clipped": sorted(set(clipped))}

def pipeline_rows(pods=(2, 4), micro=(4, 8, 32)):
    """Inter-pod 1F1B pipeline theory (PR 5): bubble fraction + boundary
    transfer exposure on the largest-workload ladder rung, per (p, m).

    Each row carries BOTH the closed-form bubble ``(p-1)/(m+p-1)``
    (core/theory.pipeline_bubble_fraction) and the bubble of the actual
    simulated 1F1B table (parallel/pipeline.schedule_1f1b) — the two must
    agree exactly, which is asserted here so the emitted
    ``theory_pipeline_*`` rows are self-checking.
    """
    from repro.parallel.pipeline import schedule_1f1b
    name, h, N, layers = WORKLOADS[-1]
    beta = PACKAGES["standard"]
    rows = []
    for p_ in pods:
        for m in micro:
            cp = T.CommParams(N=N, beta=beta, b=8, s=2048, h=h)
            sp = T.SystemParams(comm=cp, flops_per_device=DIE_FLOPS,
                                dram_channels=max(8, int(N ** 0.5) * 4))
            pt = T.pipeline_step_time(sp, p_, m, layers,
                                      pod_beta=POD_BETA)
            sched = schedule_1f1b(p_, m)
            frac = T.pipeline_bubble_fraction(p_, m)
            assert abs(sched.bubble_fraction - frac) < 1e-12, (
                p_, m, sched.bubble_fraction, frac)
            rows.append({
                "workload": name, "pods": p_, "micro": m,
                "bubble_theory": frac,
                "bubble_schedule": sched.bubble_fraction,
                "makespan_ticks": sched.makespan,
                "boundary_comm_s": pt["boundary_comm"],
                "exposed_boundary_s": pt["exposed_boundary"],
                "total_s": pt["total"],
            })
    return rows


# the paper's workload ladder (§VI-A): h doubles, N scales by 4x
WORKLOADS = [
    ("tinyllama-1.1b", 2048, 16, 22),
    ("llama2-7b", 4096, 64, 32),
    ("llama2-70b", 8192, 256, 80),
    ("llama3.1-405b", 16384, 1024, 126),
]
# Calibration constants: the paper's RTL/synthesis flow is not portable, so
# these are fitted so the analytical model reproduces the paper's reported
# headline ratios (5.29x/3.46x on the largest workload, standard package).
PACKAGES = {"standard": 12e9, "advanced": 48e9}   # D2D bytes/s per link
# Inter-package (pod-to-pod) bandwidth: the slow off-package tier the 1F1B
# pipeline is placed on — DRAM-channel class, ~an order below on-package D2D.
POD_BETA = 1.6e9
DIE_FLOPS = 5e12            # per-die FP32 (7nm-rescaled PE array)
E_D2D = 1.0e-12 * 8         # J/byte on-package
E_DRAM = 19e-12 * 8         # J/byte off-package
E_FLOP = 0.1e-12            # J/flop at full utilization


def run():
    rows = []
    for pkg, beta in PACKAGES.items():
        for name, h, N, layers in WORKLOADS:
            p = T.CommParams(N=N, beta=beta, b=8, s=2048, h=h)
            sp = T.SystemParams(comm=p, flops_per_device=DIE_FLOPS,
                                dram_channels=max(8, int(N ** 0.5) * 4))
            res = {}
            for m in T.METHODS:
                lt = T.layer_time(m, sp)
                comm = T.layer_comm(m, p)
                flops = T.layer_flops(p)
                act_bytes = 24 * p.b * p.s * p.h * p.bytes_per_elt
                nop_bytes = comm["transmission"] * beta * p.N   # total moved
                # energy: low PE utilization burns array power on idle lanes
                util = T.pe_utilization(m, p)
                energy = (flops * E_FLOP / util + nop_bytes * E_D2D
                          + act_bytes * E_DRAM)
                # SRAM check at the paper's minimal execution unit (one
                # mini-batch of 512 tokens, 8MB buffer) — same element width
                # as the ladder run (was hardcoded fp32=4, silently doubling
                # the activation footprint vs the bf16 rows above)
                p_min = T.CommParams(N=N, beta=beta, b=1, s=512, h=h,
                                     bytes_per_elt=p.bytes_per_elt)
                res[m] = {"latency": lt["total"] * layers,
                          "energy": energy * layers,
                          "sram_ok": T.peak_sram_bytes(m, p_min)
                          <= sp.sram_bytes}
            base = res["hecaton"]
            for m, r in res.items():
                rows.append({
                    "package": pkg, "workload": name, "method": m,
                    "latency_norm": r["latency"] / base["latency"],
                    "energy_norm": r["energy"] / base["energy"],
                    "sram_ok": r["sram_ok"],
                })
    return rows


def main(emit):
    rows = run()
    # headline: paper reports 5.29x latency / 3.46x energy vs Megatron TP on
    # the largest workload with standard package
    big = {r["method"]: r for r in rows
           if r["package"] == "standard" and r["workload"] == "llama3.1-405b"}
    emit("fig8_speedup_vs_megatron_std", 0.0,
         f"{big['flat_ring']['latency_norm']:.2f}x")
    emit("fig8_energy_vs_megatron_std", 0.0,
         f"{big['flat_ring']['energy_norm']:.2f}x")
    adv = {r["method"]: r for r in rows
           if r["package"] == "advanced" and r["workload"] == "llama3.1-405b"}
    emit("fig8_speedup_vs_megatron_adv", 0.0,
         f"{adv['flat_ring']['latency_norm']:.2f}x")
    emit("fig8_speedup_vs_optimus_std", 0.0,
         f"{big['optimus']['latency_norm']:.2f}x")
    emit("fig8_sram_overflow_others", 0.0,
         f"flat={big['flat_ring']['sram_ok']},opt={big['optimus']['sram_ok']},"
         f"hec={big['hecaton']['sram_ok']}")
    # overlap-aware theory: hecaton per-mode exposed-NoP latency, largest
    # workload (keeps Table III comparable to the per-mode HLO measurements)
    for cd in ("bf16", "int8"):
        suffix = "" if cd == "bf16" else f"_{cd}"
        ov = [r for r in overlap_rows(comm_dtype=cd)
              if r["workload"] == "llama3.1-405b"]
        for r in ov:
            bw = r["eff_bandwidth"]
            bw_s = "inf" if bw == float("inf") else f"{bw/1e9:.0f}GBps"
            emit(f"theory_overlap_{r['mode']}{suffix}", 0.0,
                 f"{r['latency_norm']:.3f}x_bulk/effbw={bw_s}")
    # inter-pod 1F1B pipeline theory (PR 5): bubble fraction per (pods,
    # microbatches) — the simulated schedule must match (p-1)/(m+p-1),
    # asserted inside pipeline_rows so these rows are self-checking
    pipe = pipeline_rows()
    for r in pipe:
        emit(f"theory_pipeline_p{r['pods']}_m{r['micro']}", 0.0,
             f"bubble={r['bubble_theory']:.4f}"
             f"/sched={r['bubble_schedule']:.4f}"
             f"/exposed={r['exposed_boundary_s']*1e3:.2f}ms")
    return {"methods": rows, "pipeline": pipe}


if __name__ == "__main__":
    for r in run():
        print(r)
