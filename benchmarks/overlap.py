"""Wall-time comparison of bulk vs ring vs bidir vs fused collective matmuls.

Times one Hecaton FFN block and one seq-scatter linear (forward + backward)
per ``ParallelConfig.overlap`` mode on a multi-device CPU mesh and emits
``overlap_*`` rows with per-step time and speedup vs the bulk path.

Caveat printed into the derived column: a host-CPU mesh emulates the topology
but has no async collective engine, so the ring decomposition pays its loop
overhead without the latency hiding a TPU/GPU scheduler provides — the numbers
here track HLO structure (collective-permute chains, step counts), while the
byte accounting in hlo_compare.py is the hardware-independent signal.  The
``fused`` mode on a backend without remote-DMA support runs the Pallas ring
kernels' interpret/ppermute-emulated path (kernels/ring_matmul.py) — still
timed, flagged as emulated; a mode that fails outright is skipped gracefully
with the error recorded in its row.

Runs in a subprocess (needs its own XLA device-count flag).
CLI: ``python benchmarks/overlap.py [--modes none,ring,bidir,fused]``.
"""

import json

DEFAULT_MODES = ("none", "ring", "bidir", "fused")

SCRIPT_TMPL = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import time
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro import compat
from repro.core import hecaton as H

MODES = __MODES__
mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2), ("data", "mx", "my"))
B, T, Hd, F = 8, 256, 256, 1024
key = jax.random.PRNGKey(0)
k1, k2, k3 = jax.random.split(key, 3)
x = jax.device_put(jax.random.normal(k1, (B, T, Hd), jnp.float32),
                   NamedSharding(mesh, P("data", "mx", "my")))
w1 = jax.device_put(jax.random.normal(k2, (Hd, F), jnp.float32) / Hd ** 0.5,
                    NamedSharding(mesh, P("my", "mx")))
w2 = jax.device_put(jax.random.normal(k3, (F, Hd), jnp.float32) / F ** 0.5,
                    NamedSharding(mesh, P("mx", "my")))


def timeit(fn, *args, iters=10):
    jax.block_until_ready(fn(*args))          # warm up once (compile + run)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


out = {}
for ov in MODES:
    def ffn_step(x, w1, w2, _ov=ov):
        def f(*a):
            return H.ffn_block(*a, mesh=mesh, act_fn=jax.nn.silu,
                               t_ax="mx", h_ax="my", overlap=_ov).sum()
        return jax.grad(f, argnums=(0, 1, 2))(x, w1, w2)

    def lin_step(x, w1, _ov=ov):
        def f(*a):
            return H.linear_seq_scatter(*a, mesh=mesh, t_ax="mx", h_ax="my",
                                        overlap=_ov).sum()
        return jax.grad(f, argnums=(0, 1))(x, w1)

    try:
        row = {"ffn_us": timeit(jax.jit(ffn_step), x, w1, w2),
               "linear_us": timeit(jax.jit(lin_step), x, w1)}
        if ov == "fused" and not compat.remote_dma_supported():
            row["note"] = "interpret-emulated"
        out[ov] = row
    except Exception as e:                     # skip a broken mode gracefully
        out[ov] = {"error": f"{type(e).__name__}: {e}"[:200]}
print("RESULT " + json.dumps(out))
'''


def run(modes=DEFAULT_MODES):
    from benchmarks.hlo_compare import _run_script
    return _run_script(SCRIPT_TMPL.replace("__MODES__",
                                           json.dumps(list(modes))))


def main(emit, modes=DEFAULT_MODES):
    out = run(modes)
    if "error" in out:
        emit("overlap_bench", 0.0, "ERROR")
        return out
    bulk = out.get("none", {})
    for kind in ("ffn", "linear"):
        for mode in modes:
            row = out.get(mode, {})
            if "error" in row:
                emit(f"overlap_{kind}_{mode}", 0.0, f"SKIP:{row['error']}")
                continue
            us = row[f"{kind}_us"]
            if mode == "none":
                derived = "bulk-baseline"
            else:
                base = bulk.get(f"{kind}_us")
                derived = (f"{base/us:.2f}x_vs_bulk(cpu-emulated)" if base
                           else "no-bulk-baseline")
                if row.get("note"):
                    derived += f"({row['note']})"
            emit(f"overlap_{kind}_{mode}", us, derived)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--modes", default=",".join(DEFAULT_MODES),
                    help="comma-separated overlap modes to time")
    args = ap.parse_args()
    rows = []
    main(lambda n, us, d: rows.append(f"{n},{us:.2f},{d}"),
         modes=tuple(m for m in args.modes.split(",") if m))
    print("name,us_per_call,derived")
    for r in rows:
        print(r)
