"""Kernel micro-benchmarks (wall time of the jnp reference path on this host;
the Pallas path is TPU-targeted and validated in interpret mode by tests).

``--modes`` selects which overlap-mode kernels to time alongside the
references: ``fused`` adds the single-die Pallas tile matmul used inside the
fused ring kernels (kernels/ring_matmul.py).  On a backend without remote-DMA
support the fused row times the interpret path (flagged in the derived
column) rather than being dropped, and any kernel that fails to build is
skipped gracefully with the error in its row.
"""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref as R

DEFAULT_MODES = ("none", "fused")


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))     # warm up exactly once (compile + run)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(modes=DEFAULT_MODES):
    rows = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    x = jax.random.normal(ks[0], (512, 512), jnp.float32)
    w = jax.random.normal(ks[1], (512, 512), jnp.float32)
    mm = jax.jit(lambda a, b: R.matmul_ref(a, b, act="gelu"))
    rows.append(("micro_matmul_512_gelu", _time(mm, x, w),
                 f"{2*512**3/1e9:.2f}GF"))
    if "fused" in modes:
        try:
            from repro import compat
            from repro.kernels import ring_matmul as RM
            emulated = not compat.remote_dma_supported()
            tile = jax.jit(lambda a, b: RM.tile_matmul(a, b))
            note = "ring-kernel-tile" + ("(interpret)" if emulated else "")
            rows.append(("micro_ring_matmul_tile_512",
                         _time(tile, x, w, iters=2 if emulated else 5), note))
        except Exception as e:          # no Pallas on this backend: skip row
            rows.append(("micro_ring_matmul_tile_512", 0.0,
                         f"SKIP:{type(e).__name__}"))
    q = jax.random.normal(ks[2], (1, 8, 512, 64), jnp.float32)
    k = jax.random.normal(ks[3], (1, 4, 512, 64), jnp.float32)
    v = jax.random.normal(ks[4], (1, 4, 512, 64), jnp.float32)
    att = jax.jit(lambda a, b, c: R.attention_ref(a, b, c, causal=True))
    rows.append(("micro_attention_512", _time(att, q, k, v), "gqa2"))
    xs = jax.random.normal(ks[5], (1, 512, 8, 64), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[6], (1, 512, 8), jnp.float32))
    A = -jnp.exp(jnp.zeros((8,)))
    B = jax.random.normal(ks[7], (1, 512, 1, 64), jnp.float32)
    from repro.models.ssm import ssd_chunked
    ssd = jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0])
    rows.append(("micro_ssd_512", _time(ssd, xs, dt, A, B, B), "chunk128"))
    return rows


def main(emit, modes=DEFAULT_MODES):
    rows = run(modes)
    for name, us, d in rows:
        emit(name, us, d)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--modes", default=",".join(DEFAULT_MODES),
                    help="comma-separated modes (e.g. none,fused)")
    args = ap.parse_args()
    for name, us, d in run(tuple(m for m in args.modes.split(",") if m)):
        print(f"{name},{us:.2f},{d}")
