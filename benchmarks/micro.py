"""Kernel micro-benchmarks (wall time of the jnp reference path on this host;
the Pallas path is TPU-targeted and validated in interpret mode by tests)."""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref as R


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))     # warm up exactly once (compile + run)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    x = jax.random.normal(ks[0], (512, 512), jnp.float32)
    w = jax.random.normal(ks[1], (512, 512), jnp.float32)
    mm = jax.jit(lambda a, b: R.matmul_ref(a, b, act="gelu"))
    rows.append(("micro_matmul_512_gelu", _time(mm, x, w),
                 f"{2*512**3/1e9:.2f}GF"))
    q = jax.random.normal(ks[2], (1, 8, 512, 64), jnp.float32)
    k = jax.random.normal(ks[3], (1, 4, 512, 64), jnp.float32)
    v = jax.random.normal(ks[4], (1, 4, 512, 64), jnp.float32)
    att = jax.jit(lambda a, b, c: R.attention_ref(a, b, c, causal=True))
    rows.append(("micro_attention_512", _time(att, q, k, v), "gqa2"))
    xs = jax.random.normal(ks[5], (1, 512, 8, 64), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[6], (1, 512, 8), jnp.float32))
    A = -jnp.exp(jnp.zeros((8,)))
    B = jax.random.normal(ks[7], (1, 512, 1, 64), jnp.float32)
    from repro.models.ssm import ssd_chunked
    ssd = jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0])
    rows.append(("micro_ssd_512", _time(ssd, xs, dt, A, B, B), "chunk128"))
    return rows


def main(emit):
    for name, us, d in run():
        emit(name, us, d)
    return run()
