"""Paper Table IV — proportion of link latency in system latency (alpha=10ns)."""
from repro.core import theory as T

WORKLOADS = [("llama-1.1B", 2048, 16, 22), ("llama-7B", 4096, 64, 32),
             ("llama-70B", 8192, 256, 80), ("llama-405B", 16384, 1024, 126)]
DIE_FLOPS = 5e12


def run():
    rows = []
    for pkg, beta in (("standard", 12e9), ("advanced", 48e9)):
        for name, h, N, layers in WORKLOADS:
            p = T.CommParams(N=N, alpha=10e-9, beta=beta, b=8, s=2048, h=h)
            sp = T.SystemParams(comm=p, flops_per_device=DIE_FLOPS,
                                dram_channels=max(8, int(N ** 0.5) * 4))
            t = T.layer_time("hecaton", sp)
            frac = t["nop_link"] / t["total"]
            rows.append({"package": pkg, "workload": name,
                         "link_latency_pct": 100 * frac})
    return rows


def main(emit):
    for r in run():
        emit(f"tab4_{r['package']}_{r['workload']}", 0.0,
             f"{r['link_latency_pct']:.3f}%")
    return run()


if __name__ == "__main__":
    for r in run():
        print(r)
