"""Quickstart: build a tiny LM, train a few steps, then serve it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.config import ParallelConfig, RunConfig, get_smoke_config
from repro.data.synthetic import SyntheticLM
from repro.models import lm
from repro.optim import adamw
from repro.parallel.context import PCtx
from repro.serve import step as SS
from repro.train import step as TS


def main():
    cfg = get_smoke_config("qwen3-0.6b")        # reduced qwen3 architecture
    rc = RunConfig("quickstart", "train", seq_len=64, global_batch=8, lr=1e-3)
    pcfg = ParallelConfig(strategy="hecaton", data=1, model=1, mx=1, my=1)

    # --- train ------------------------------------------------------------
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    step = jax.jit(TS.build_train_step(cfg, pcfg, rc, None,
                                       compute_dtype=jnp.float32),
                   donate_argnums=(0, 1))
    ds = SyntheticLM(cfg.vocab_size, rc.seq_len, rc.global_batch)
    first = last = None
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        params, opt, m = step(params, opt, batch)
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
        if i % 10 == 0:
            print(f"step {i:3d} loss {last:.4f}")
    print(f"loss {first:.3f} -> {last:.3f}")
    assert last < first, "training should reduce loss"

    # --- serve ------------------------------------------------------------
    src = RunConfig("serve", "decode", seq_len=32, global_batch=2)
    prefill = jax.jit(SS.build_prefill(cfg, pcfg, src, None,
                                       compute_dtype=jnp.float32))
    decode = jax.jit(SS.build_decode_step(cfg, pcfg, src, None,
                                          compute_dtype=jnp.float32))
    prompt = {"tokens": jnp.asarray(ds.batch_at(99)["tokens"][:2, :16])}
    logits, caches = prefill(params, prompt)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    gen = [tok]
    for i in range(8):
        pos = jnp.full((2, 1), 16 + i, jnp.int32)
        logits, caches = decode(params, caches, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        gen.append(tok)
    print("generated:", jnp.concatenate(gen, 1)[0])
    print("quickstart OK")


if __name__ == "__main__":
    main()
