"""End-to-end training driver: a ~110M-parameter decoder LM trained for a few
hundred steps on synthetic data with checkpointing + fault supervision.

    PYTHONPATH=src python examples/train_e2e.py            # full (~110M, slow on CPU)
    PYTHONPATH=src python examples/train_e2e.py --quick    # ~10M CI-sized run

On a real TPU slice this exact script scales out: pass --mesh-devices N.
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--quick", action="store_true")
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--ckpt", default="/tmp/repro_e2e_ckpt")
args = ap.parse_args()

import jax
import jax.numpy as jnp

from repro.config import CheckpointConfig, ModelConfig, ParallelConfig, \
    RunConfig
from repro.checkpoint.manager import make_manager
from repro.data.synthetic import Prefetcher, SyntheticLM
from repro.models import lm
from repro.optim import adamw
from repro.runtime.fault import StepTimer
from repro.train import loop as train_loop
from repro.train import step as TS

if args.quick:
    cfg = ModelConfig(name="e2e-10m", family="dense", num_layers=4,
                      d_model=256, num_heads=4, num_kv_heads=2, d_ff=1024,
                      vocab_size=8192, mlp_kind="swiglu")
    seq, batch = 64, 4
else:
    cfg = ModelConfig(name="e2e-110m", family="dense", num_layers=12,
                      d_model=640, num_heads=10, num_kv_heads=5, d_ff=2560,
                      vocab_size=50_304, mlp_kind="swiglu")
    seq, batch = 256, 8

print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
rc = RunConfig("e2e", "train", seq, batch, lr=6e-4, warmup_steps=30)
pcfg = ParallelConfig(strategy="hecaton", data=1, model=1, mx=1, my=1,
                      microbatches=2)

params = lm.init_params(cfg, jax.random.PRNGKey(0))
opt = adamw.init(params)
ts = jax.jit(TS.build_train_step(cfg, pcfg, rc, None,
                                 compute_dtype=jnp.float32),
             donate_argnums=(0, 1))
ds = SyntheticLM(cfg.vocab_size, seq, batch)
it = Prefetcher(iter(ds))
# async double-buffered saves: the boundary step only snapshots to the host
# staging arena; serialization+publish overlap the following steps
ckpt = make_manager(args.ckpt, CheckpointConfig(every=100, async_=True))
state = {"params": params, "opt_state": opt}
state = train_loop.train(ts, state, it, num_steps=args.steps, ckpt=ckpt,
                         ckpt_every=100, log_every=20, timer=StepTimer())
it.close()
ckpt.close()
h = state["history"]
print(f"loss {h[0][1]:.3f} -> {h[-1][1]:.3f} over {args.steps} steps")
assert h[-1][1] < h[0][1]
