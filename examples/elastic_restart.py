"""Fault tolerance demo: inject failures mid-training, supervisor restarts from
the latest atomic checkpoint, and the resumed trajectory is BIT-EXACT against
an uninterrupted baseline run (the crash-resume divergence check CI enforces).

Checkpointing runs through the ASYNC double-buffered manager with a 2-writer
group: boundary steps only snapshot into the host staging arena;
serialization + the two-phase quorum publish (per-writer shard dirs +
checksummed partial manifests, then the atomic global manifest) happen off
the training thread, and the supervisor's ``ckpt=`` fence aborts any
in-flight save from a dead incarnation so a restart only ever restores a
fully-published step — every restored shard crc32-verified against its
manifest entry (docs/DESIGN.md §7).

    PYTHONPATH=src python examples/elastic_restart.py
"""
import shutil

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import make_manager
from repro.config import CheckpointConfig, ModelConfig, ParallelConfig, \
    RunConfig
from repro.data.synthetic import SyntheticLM
from repro.models import lm
from repro.optim import adamw
from repro.runtime.fault import FailureInjector, run_supervised
from repro.train import loop as train_loop
from repro.train import step as TS

CKPT = "/tmp/repro_elastic_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = ModelConfig(name="elastic-demo", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=256, mlp_kind="swiglu")
rc = RunConfig("e", "train", 32, 8, lr=1e-3)
pcfg = ParallelConfig(strategy="hecaton", data=1, model=1, mx=1, my=1)
TOTAL = 60
ckpt = make_manager(CKPT, CheckpointConfig(every=10, keep=3, async_=True,
                                           writers=2, verify=True))
injector = FailureInjector({17: "chip down", 38: "host unreachable"})
ts = jax.jit(TS.build_train_step(cfg, pcfg, rc, None,
                                 compute_dtype=jnp.float32),
             donate_argnums=(0, 1))
ds = SyntheticLM(cfg.vocab_size, rc.seq_len, rc.global_batch)


def fresh_state():
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return {"params": params, "opt_state": adamw.init(params)}


def batches(lo, hi):
    it = (ds.batch_at(s) for s in range(lo, hi))
    return ({k: jnp.asarray(v) for k, v in b.items()} for b in it)


# ---------------------------------------------------------------------------
# uninterrupted baseline: the loss history every resumed run must reproduce
# ---------------------------------------------------------------------------
baseline = train_loop.train(ts, fresh_state(), batches(0, TOTAL),
                            num_steps=TOTAL, log_every=20,
                            log_fn=lambda *a: None)
baseline_hist = dict(baseline["history"])
# per-step history (train/loop.py records every step): show endpoints only
print(f"baseline (uninterrupted): {len(baseline_hist)} steps, "
      f"first {baseline_hist[0]:.4f} last {baseline_hist[TOTAL - 1]:.4f}")


# ---------------------------------------------------------------------------
# supervised run with injected failures + async checkpointing
# ---------------------------------------------------------------------------
def make_state(_):
    state = fresh_state()
    start = 0
    if ckpt.latest_step() is not None:
        restored, start = ckpt.restore(
            {"params": state["params"], "opt_state": state["opt_state"]})
        state = {"params": restored["params"],
                 "opt_state": restored["opt_state"]}
        print(f"  [supervisor] restored step {start}")
    return state, start


def run_steps(state, start, inc):
    print(f"  [supervisor] incarnation {inc.index} from step {start}")
    return train_loop.train(ts, state, batches(start, TOTAL),
                            start_step=start, num_steps=TOTAL,
                            ckpt=ckpt, ckpt_every=10, log_every=20,
                            injector=injector)


state, incarnations = run_supervised(make_state, run_steps, max_restarts=4,
                                     ckpt=ckpt)
ckpt.close()
print(f"survived {len(injector.log)} injected failures "
      f"({incarnations} incarnations): {injector.log}")
assert incarnations == 3 and state["history"][-1][0] == TOTAL - 1

# crash-resume divergence check: every loss the resumed incarnation logged
# must equal the uninterrupted baseline's at the same step, bit-exact
resumed = dict(state["history"])
assert resumed, "resumed run logged no history"
for step, loss in sorted(resumed.items()):
    assert baseline_hist[step] == loss, (
        f"resumed loss diverged at step {step}: "
        f"{loss!r} != baseline {baseline_hist[step]!r}")
print(f"resumed losses bit-exact vs baseline at all "
      f"{len(resumed)} recorded steps")
print("elastic_restart OK")
