"""Fault tolerance demo: inject failures mid-training, supervisor restarts from
the latest atomic checkpoint, and the final run resumes on a RESHARDED mesh
(elastic rescale: checkpoint written single-device, restored onto a 4-device
mesh) with bit-exact data continuation.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import shutil

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.config import ModelConfig, ParallelConfig, RunConfig
from repro.data.synthetic import SyntheticLM
from repro.models import lm
from repro.optim import adamw
from repro.runtime.fault import FailureInjector, run_supervised
from repro.train import loop as train_loop
from repro.train import step as TS

CKPT = "/tmp/repro_elastic_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = ModelConfig(name="elastic-demo", family="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=256, mlp_kind="swiglu")
rc = RunConfig("e", "train", 32, 8, lr=1e-3)
pcfg = ParallelConfig(strategy="hecaton", data=1, model=1, mx=1, my=1)
TOTAL = 60
ckpt = CheckpointManager(CKPT)
injector = FailureInjector({17: "chip down", 38: "host unreachable"})
ts = jax.jit(TS.build_train_step(cfg, pcfg, rc, None,
                                 compute_dtype=jnp.float32),
             donate_argnums=(0, 1))
ds = SyntheticLM(cfg.vocab_size, rc.seq_len, rc.global_batch)


def make_state(_):
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    start = 0
    if ckpt.latest_step() is not None:
        restored, start = ckpt.restore({"params": params, "opt_state": opt})
        params, opt = restored["params"], restored["opt_state"]
        print(f"  [supervisor] restored step {start}")
    return {"params": params, "opt_state": opt}, start


def run_steps(state, start, inc):
    print(f"  [supervisor] incarnation {inc.index} from step {start}")
    it = (ds.batch_at(s) for s in range(start, TOTAL))
    it = ({k: jnp.asarray(v) for k, v in b.items()} for b in it)
    return train_loop.train(ts, state, it, start_step=start, num_steps=TOTAL,
                            ckpt=ckpt, ckpt_every=10, log_every=20,
                            injector=injector)


state, incarnations = run_supervised(make_state, run_steps, max_restarts=4)
print(f"survived {len(injector.log)} injected failures "
      f"({incarnations} incarnations): {injector.log}")
assert incarnations == 3 and state["history"][-1][0] == TOTAL - 1
print("elastic_restart OK")
