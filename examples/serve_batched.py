"""Batched serving with prefill + decode slots (continuous-batching-lite):
finished sequences are replaced by queued prompts without stopping decode.

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ParallelConfig, RunConfig, get_smoke_config
from repro.data.synthetic import SyntheticLM
from repro.models import lm
from repro.serve import step as SS

cfg = get_smoke_config("granite-34b")
B, PLEN, SMAX = 4, 12, 48
rc = RunConfig("serve", "decode", SMAX, B)
pcfg = ParallelConfig(strategy="hecaton", data=1, model=1, mx=1, my=1)
params = lm.init_params(cfg, jax.random.PRNGKey(0))
prefill = jax.jit(SS.build_prefill(cfg, pcfg,
                                   RunConfig("p", "prefill", SMAX, B), None,
                                   compute_dtype=jnp.float32))
decode = jax.jit(SS.build_decode_step(cfg, pcfg, rc, None,
                                      compute_dtype=jnp.float32))

queue = [SyntheticLM(cfg.vocab_size, PLEN, 1, seed=s).batch_at(0)["tokens"]
         for s in range(8)]
eos_after = {0: 6, 1: 10, 2: 4, 3: 8}     # synthetic per-slot stop lengths

batch0 = jnp.concatenate([jnp.asarray(queue.pop(0)) for _ in range(B)], 0)
logits, caches = prefill(params, {"tokens": batch0})
tok = jnp.argmax(logits, -1).astype(jnp.int32)
lengths = np.full(B, PLEN)
done_count, emitted = 0, 0
for step in range(24):
    pos = jnp.asarray(lengths[:, None], jnp.int32)
    logits, caches = decode(params, caches, tok, pos)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    lengths += 1
    emitted += B
    for slot in range(B):
        if lengths[slot] - PLEN >= eos_after.get(slot, 12) and queue:
            # slot finished: swap in a queued prompt (cache slot re-prefilled
            # standalone; a production server would batch these).  Cache leaves
            # are stacked [L, B, ...]: replace batch row `slot`.
            done_count += 1
            prompt = jnp.asarray(queue.pop(0))
            _, c1 = prefill(params, {"tokens": jnp.repeat(prompt, B, 0)})

            def swap(full, one):
                if full.ndim >= 2 and full.shape[1] == B:
                    return full.at[:, slot].set(one[:, slot])
                return full
            caches = jax.tree.map(swap, caches, c1)
            lengths[slot] = PLEN
            eos_after[slot] = 12
print(f"emitted {emitted} tokens, completed {done_count} sequences, "
      f"queue left {len(queue)}")
print("serve_batched OK")
