"""Markdown link-and-anchor checker (ISSUE 5 satellite).

Fails (exit 1) on dangling *intra-repo* references, the class of rot that
left ``serve/step.py`` citing a ``DESIGN.md §4`` that did not exist for
four PRs:

1. **Markdown links** ``[text](target)`` in every tracked ``*.md`` file:
   the target path must exist (relative to the containing file), and a
   ``#anchor`` fragment must match a heading of the target file under
   GitHub's slugification.  External schemes (http/https/mailto) are
   ignored; fenced code blocks are skipped.

2. **Section citations** ``docs/DESIGN.md §N`` appearing anywhere in the
   repo's ``*.py`` and ``*.md`` files: ``docs/DESIGN.md`` must contain a
   numbered ``## N.`` heading.  Bare ``DESIGN.md`` mentions require the
   file to exist at ``docs/DESIGN.md``.

Run from anywhere:  ``python tools/check_links.py [repo_root]``
Used by CI and by ``tests/test_docs.py``.
"""

from __future__ import annotations

import os
import re
import sys

SKIP_DIRS = {".git", ".github", ".pytest_cache", ".claude", "__pycache__",
             ".hypothesis"}
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
SECTION_CITE_RE = re.compile(r"DESIGN\.md\s*§\s*(\d+)")
NUMBERED_HEADING_RE = re.compile(r"^##\s+(\d+)[.·]\s")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def _walk(root: str, exts):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for f in filenames:
            if f.endswith(exts):
                yield os.path.join(dirpath, f)


def _strip_code_fences(text: str) -> str:
    """Blank out fenced code blocks so code snippets aren't parsed as links."""
    out, fenced = [], False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            fenced = not fenced
            out.append("")
            continue
        out.append("" if fenced else line)
    return "\n".join(out)


def github_slug(heading: str) -> str:
    """GitHub's anchor slugification: lowercase, drop non [word/space/-],
    spaces -> hyphens (inline code/emphasis markers removed first; in-word
    underscores are KEPT — they are word characters, not emphasis)."""
    h = re.sub(r"[`*]", "", heading).strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def md_anchors(path: str):
    anchors = set()
    with open(path, encoding="utf-8") as f:
        text = _strip_code_fences(f.read())
    for line in text.splitlines():
        m = HEADING_RE.match(line)
        if m:
            anchors.add(github_slug(m.group(2)))
    return anchors


def design_sections(design_path: str):
    if not os.path.exists(design_path):
        return None
    sections = set()
    with open(design_path, encoding="utf-8") as f:
        for line in f:
            m = NUMBERED_HEADING_RE.match(line)
            if m:
                sections.add(int(m.group(1)))
    return sections


def check(root: str):
    errors = []
    anchor_cache = {}

    def anchors_of(path):
        if path not in anchor_cache:
            anchor_cache[path] = md_anchors(path)
        return anchor_cache[path]

    # 1. markdown links
    for md in sorted(_walk(root, (".md",))):
        rel = os.path.relpath(md, root)
        with open(md, encoding="utf-8") as f:
            text = _strip_code_fences(f.read())
        for n, line in enumerate(text.splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(EXTERNAL):
                    continue
                path_part, _, frag = target.partition("#")
                base = (md if not path_part else
                        os.path.normpath(os.path.join(os.path.dirname(md),
                                                      path_part)))
                if not os.path.exists(base):
                    errors.append(f"{rel}:{n}: dangling link target "
                                  f"{target!r} (no such file {path_part!r})")
                    continue
                if frag and base.endswith(".md"):
                    if frag.lower() not in anchors_of(base):
                        errors.append(
                            f"{rel}:{n}: dangling anchor {target!r} "
                            f"(#{frag} not a heading of "
                            f"{os.path.relpath(base, root)})")

    # 2. DESIGN.md § citations (in .py and .md alike)
    design = os.path.join(root, "docs", "DESIGN.md")
    sections = design_sections(design)
    for src in sorted(_walk(root, (".py", ".md"))):
        rel = os.path.relpath(src, root)
        if rel == "ISSUE.md":        # task spec may cite by intent
            continue
        with open(src, encoding="utf-8", errors="replace") as f:
            for n, line in enumerate(f, 1):
                if "DESIGN.md" not in line:
                    continue
                if sections is None:
                    errors.append(f"{rel}:{n}: cites DESIGN.md but "
                                  f"docs/DESIGN.md does not exist")
                    continue
                for m in SECTION_CITE_RE.finditer(line):
                    sec = int(m.group(1))
                    if sec not in sections:
                        errors.append(
                            f"{rel}:{n}: cites DESIGN.md §{sec} but "
                            f"docs/DESIGN.md has sections "
                            f"{sorted(sections)}")
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = os.path.abspath(argv[0] if argv else
                           os.path.join(os.path.dirname(
                               os.path.abspath(__file__)), ".."))
    errors = check(root)
    if errors:
        print(f"check_links: {len(errors)} dangling reference(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print("check_links: all intra-repo links and section citations resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
