"""Dump largest tensors + collectives (with op_name metadata) from a dry-run cell."""
import os, sys, re
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
sys.argv, argv = sys.argv[:1], sys.argv
import jax
from repro.launch.dryrun import lower_cell
from repro.roofline.hlo import _shapes_in, DTYPE_BYTES, group_size
import math

arch, shape, strategy, mp = argv[1], argv[2], argv[3], argv[4] == "multi"
lowered, meta = lower_cell(arch, shape, strategy, mp)
compiled = lowered.compile()
txt = compiled.as_text()
rows, colls = [], []
for ln in txt.splitlines():
    ls = ln.strip()
    m = re.match(r"(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)", ls)
    if not m: continue
    rhs = m.group(2)
    shapes = _shapes_in(rhs.split(" ")[0] if not rhs.startswith("(") else rhs.split(")")[0])
    b = sum(DTYPE_BYTES[dt]*math.prod(d or [1]) for dt, d in shapes)
    op = re.search(r"\]\{?[0-9,]*\}?\s+([a-z\-]+)\(", rhs)
    opname = op.group(1) if op else "?"
    meta_m = re.search(r'op_name="([^"]+)"', ls)
    mn = meta_m.group(1)[-110:] if meta_m else ""
    if opname in ("all-gather","all-reduce","reduce-scatter","all-to-all","collective-permute"):
        colls.append((b, opname, group_size(ls), mn))
    if b > 100e6 and opname not in ("parameter","tuple","get-tuple-element"):
        rows.append((b, opname, mn))
rows.sort(reverse=True)
colls.sort(reverse=True)
print("=== largest tensors ===")
for b, op, mn in rows[:25]:
    print(f"{b/2**30:8.2f}GiB {op:18s} {mn}")
print("=== largest collectives ===")
for b, op, g, mn in colls[:25]:
    print(f"{b/2**20:8.1f}MiB {op:18s} g={g:3d} {mn}")
